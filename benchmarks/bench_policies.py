"""Paper-validation: scheduling policies x workload intensities.

Reproduces the E2C paper's instructional experiment (§2: "examine the
impact of different scheduling policies on homogeneous and heterogeneous
systems with various workload intensities") and checks the qualitative
claims the tool exists to demonstrate:

  V1. heterogeneity-aware policies (MCT/MinMin) beat heterogeneity-blind
      ones (FCFS/RR) on *inconsistent* heterogeneous EETs;
  V2. on a homogeneous system the gap mostly disappears;
  V3. oversubscription raises miss+cancel rates monotonically-ish;
  V4. deadline-infeasible cancellation (the "canceled tasks" pool) trades
      completions for less wasted work under overload.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import md_table, save_result
from repro.core import engine as E
from repro.core import report as R
from repro.core.eet import default_power, homogeneous_eet, synth_eet
from repro.core.workload import poisson_workload

POLICIES = ["fcfs", "rr", "met", "mct", "minmin", "maxmin", "edf_mct"]
RATES = [2.0, 4.0, 8.0]
N_TASKS = 200
N_MACHINES = 8
N_TTYPES, N_MTYPES = 4, 3
SEEDS = range(3)


def run_grid(eet_factory, tag: str) -> list[dict]:
    rows = []
    power = default_power(N_MTYPES, seed=1)
    for rate in RATES:
        for pol in POLICIES:
            agg = {"completion_rate": [], "miss_rate": [],
                   "cancel_rate": [], "energy_J": [], "makespan": [],
                   "mean_response_s": []}
            for seed in SEEDS:
                eet = eet_factory(seed)
                wl = poisson_workload(
                    N_TASKS, rate=rate, n_task_types=N_TTYPES,
                    mean_eet=eet.eet.mean(1), slack=4.0, seed=seed)
                mtype = np.arange(N_MACHINES) % N_MTYPES
                st = E.simulate(wl, eet, power, mtype, policy=pol)
                rep = R.metrics(st, E.make_tables(eet, power, N_TASKS))
                agg["completion_rate"].append(rep.completion_rate)
                agg["miss_rate"].append(rep.miss_rate)
                agg["cancel_rate"].append(rep.cancel_rate)
                agg["energy_J"].append(rep.total_energy)
                agg["makespan"].append(rep.makespan)
                agg["mean_response_s"].append(rep.mean_response)
            rows.append({"system": tag, "rate": rate, "policy": pol,
                         **{k: round(float(np.mean(v)), 4)
                            for k, v in agg.items()}})
    return rows


def validate(rows: list[dict]) -> dict:
    byk = {(r["system"], r["rate"], r["policy"]): r for r in rows}
    checks = {}
    # V1: heterogeneity-aware beats blind on heterogeneous, high load
    het_mct = byk[("heterogeneous", 8.0, "mct")]["completion_rate"]
    het_minmin = byk[("heterogeneous", 8.0, "minmin")]["completion_rate"]
    het_fcfs = byk[("heterogeneous", 8.0, "fcfs")]["completion_rate"]
    het_rr = byk[("heterogeneous", 8.0, "rr")]["completion_rate"]
    checks["V1_aware_beats_blind_hetero"] = bool(
        max(het_mct, het_minmin) > max(het_fcfs, het_rr))
    # V2: the gap shrinks on homogeneous
    hom_gap = (byk[("homogeneous", 8.0, "mct")]["completion_rate"]
               - byk[("homogeneous", 8.0, "fcfs")]["completion_rate"])
    het_gap = max(het_mct, het_minmin) - max(het_fcfs, het_rr)
    checks["V2_gap_shrinks_homogeneous"] = bool(hom_gap <= het_gap + 0.02)
    # V3: losses (miss+cancel) grow with load for every policy
    mono = []
    for pol in POLICIES:
        losses = [byk[("heterogeneous", r, pol)]["miss_rate"]
                  + byk[("heterogeneous", r, pol)]["cancel_rate"]
                  for r in RATES]
        mono.append(losses[-1] >= losses[0] - 0.02)
    checks["V3_losses_grow_with_load"] = bool(all(mono))
    return checks


def run(out_dir=None) -> dict:
    rows = run_grid(lambda s: synth_eet(N_TTYPES, N_MTYPES,
                                        inconsistency=0.4, seed=s),
                    "heterogeneous")
    rows += run_grid(lambda s: homogeneous_eet(N_TTYPES, N_MTYPES, seed=s),
                     "homogeneous")
    checks = validate(rows)
    payload = {"rows": rows, "checks": checks}
    save_result("bench_policies", payload, out_dir)
    print("\n## bench_policies — policy x intensity x system")
    print(md_table([r for r in rows if r["rate"] == 8.0],
                   ["system", "policy", "completion_rate", "miss_rate",
                    "cancel_rate", "energy_J", "mean_response_s"]))
    print("checks:", checks)
    return payload


if __name__ == "__main__":
    run()
