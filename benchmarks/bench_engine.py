"""Simulator engine throughput: the "cheap controlled studies" claim.

The paper's motivation is that real-infrastructure studies are cost- and
time-prohibitive.  The quantitative claim of this reproduction is that
the vectorized engine makes *simulated* studies cheap at scale:

  T1. one jit'd replica beats the plain-Python reference engine;
  T2. vmapped replicas amortize: events/sec grows ~linearly with the
      replica count until the host saturates (on TPU this axis is then
      sharded over the pod — launch/sim.py).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import md_table, save_result
from repro.core import engine as E
from repro.core import ref_engine as RE
from repro.core import schedulers as P
from repro.launch.sim import (build_scenario_sweep, build_sim_sweep,
                              build_traced_sweep, make_replicas,
                              make_scenario_replicas,
                              make_workflow_replicas, run_grouped_sweep)

N_TASKS, N_MACHINES = 128, 16


def time_sweep(n_replicas: int) -> tuple[float, float]:
    inputs = make_replicas(n_replicas, N_TASKS, N_MACHINES, seed=0)
    sweep = jax.jit(build_sim_sweep(N_TASKS, N_MACHINES))
    out = sweep(*inputs)                       # compile + warm
    jax.block_until_ready(out["completed"])
    t0 = time.perf_counter()
    out = sweep(*inputs)
    jax.block_until_ready(out["completed"])
    dt = time.perf_counter() - t0
    return dt, dt / n_replicas


def time_scenario_sweep(n_replicas: int) -> tuple[float, float]:
    """Dynamic-scenario replicas (failure traces + DVFS + preemption)."""
    inputs = make_scenario_replicas(n_replicas, N_TASKS, N_MACHINES, seed=0)
    sweep = jax.jit(build_scenario_sweep(N_TASKS, N_MACHINES))
    out = sweep(*inputs)                       # compile + warm
    jax.block_until_ready(out["completed"])
    t0 = time.perf_counter()
    out = sweep(*inputs)
    jax.block_until_ready(out["completed"])
    dt = time.perf_counter() - t0
    return dt, dt / n_replicas


def time_traced_sweep(n_replicas: int) -> tuple[float, float]:
    """Replicas with in-jit trace capture on (EXPERIMENTS.md §Perf —
    the measured cost of the masked trace writes + snapshots)."""
    inputs = make_replicas(n_replicas, N_TASKS, N_MACHINES, seed=0)
    sweep = jax.jit(build_traced_sweep(N_TASKS, N_MACHINES))
    out, _ = sweep(*inputs)                    # compile + warm
    jax.block_until_ready(out["completed"])
    t0 = time.perf_counter()
    out, traces = sweep(*inputs)
    jax.block_until_ready(traces.n_rows)
    dt = time.perf_counter() - t0
    return dt, dt / n_replicas


def time_learned_dispatch(n_replicas: int) -> tuple[float, float]:
    """Learned-policy dispatch overhead, decision-for-decision.

    The MLP policy is run with the MCT-equivalent warm start
    (``neural.mct_mlp_params``), so both groups take *identical*
    scheduling decisions and event trajectories — the timing difference
    is purely the per-drain-step feature build + forward pass.  Both use
    the policy-grouped path so the heuristic baseline doesn't pay for
    the learned branch (batched lax.switch computes every branch).
    """
    from repro.core import neural as NN
    pp = NN.mct_mlp_params()
    base = make_replicas(n_replicas, N_TASKS, N_MACHINES,
                         policies=["mct"], seed=0)
    learned = base[:3] + (jnp.full_like(base[3], P.POLICY_IDS["mlp"]),)
    times = []
    for inputs, kw in ((base, {}), (learned, {"policy_params": pp})):
        run_grouped_sweep(inputs, **kw)              # compile + warm
        t0 = time.perf_counter()
        run_grouped_sweep(inputs, **kw)
        times.append((time.perf_counter() - t0) / n_replicas)
    return times[0], times[1]                        # (mct, mlp) s/replica


def time_workflow_sweep(n_replicas: int) -> tuple[float, float, float]:
    """DAG-engine rows (docs/workflows.md, EXPERIMENTS.md §Perf).

    Three per-replica timings at the same N, all single-policy (mct) so
    the drain logic is identical:

    * ``chain``   — a fully sequential chain workflow (the dependency-
      release phase is doing maximal work: one release per task);
    * ``inert``   — the *independent* workload run with an all(-1)
      parent table, i.e. the ``has_deps`` machinery compiled in but
      semantically idle — the pure machinery cost T7 bounds;
    * ``plain``   — the same independent workload with ``parents=None``
      (the pre-DAG engine, T7's baseline).
    """
    wf_in = make_workflow_replicas(n_replicas, N_TASKS, N_MACHINES,
                                   shapes=("chain",), policies=["mct"],
                                   seed=0)
    chain_inputs = wf_in[:4] + (wf_in[5],)
    dag_sweep = jax.jit(build_sim_sweep(N_TASKS, N_MACHINES,
                                        workflow=True))
    base = make_replicas(n_replicas, N_TASKS, N_MACHINES,
                         policies=["mct"], seed=0)
    inert_inputs = base + (jnp.full((n_replicas, N_TASKS, 1), -1,
                                    jnp.int32),)
    plain_sweep = jax.jit(build_sim_sweep(N_TASKS, N_MACHINES))
    times = []
    for fn, inputs in ((dag_sweep, chain_inputs),
                       (dag_sweep, inert_inputs),
                       (plain_sweep, base)):
        out = fn(*inputs)                      # compile + warm
        jax.block_until_ready(out["completed"])
        t0 = time.perf_counter()
        out = fn(*inputs)
        jax.block_until_ready(out["completed"])
        times.append((time.perf_counter() - t0) / n_replicas)
    return times[0], times[1], times[2]        # (chain, inert, plain)


def run(out_dir=None, smoke: bool = False) -> dict:
    # ref engine indexes tuple fields positionally; rebuild host-side
    inputs = make_replicas(2, N_TASKS, N_MACHINES, seed=0)
    t0 = time.perf_counter()
    for i in range(2):
        arr = jax.tree.map(lambda x: np.asarray(x[i]), inputs)
        tt, mt, tb, pid = arr
        RE.simulate_ref(tt.arrival, tt.type_id, tt.deadline, tb.eet,
                        tb.power, mt, policy=P.POLICY_NAMES[int(pid)],
                        noise=tb.noise)
    ref_per_replica = (time.perf_counter() - t0) / 2

    sizes = (1, 8, 32) if smoke else (1, 8, 64, 256)
    big = sizes[-1]
    rows = []
    per_replica_1 = None
    for n in sizes:
        total, per = time_sweep(n)
        if n == 1:
            per_replica_1 = per
        rows.append({"replicas": n, "total_s": round(total, 4),
                     "per_replica_ms": round(per * 1e3, 3),
                     "replicas_per_s": round(n / total, 1)})
    per_replica_big = rows[-1]["per_replica_ms"]

    # policy-grouped variant: batched lax.switch computes every policy
    # branch per replica; grouping makes the policy a compile-time
    # constant (see launch/sim.run_grouped_sweep)
    inputs = make_replicas(big, N_TASKS, N_MACHINES, seed=0)
    run_grouped_sweep(inputs)                   # compile + warm
    t0 = time.perf_counter()
    run_grouped_sweep(inputs)
    grouped_per = (time.perf_counter() - t0) / big
    rows.append({"replicas": f"{big} (policy-grouped)",
                 "total_s": round(grouped_per * big, 4),
                 "per_replica_ms": round(grouped_per * 1e3, 3),
                 "replicas_per_s": round(1 / grouped_per, 1)})

    # dynamic-scenario variant: availability traces + DVFS + preemption
    # add an event phase and masks; T4 bounds their overhead
    scen_n = 8 if smoke else 64
    scen_total, scen_per = time_scenario_sweep(scen_n)
    rows.append({"replicas": f"{scen_n} (scenario)",
                 "total_s": round(scen_total, 4),
                 "per_replica_ms": round(scen_per * 1e3, 3),
                 "replicas_per_s": round(scen_n / scen_total, 1)})
    static_same_n = next(r for r in rows
                         if r["replicas"] == scen_n)["per_replica_ms"]

    # traced variant: TraceBuffer recording inside the jitted loop; the
    # default-off path must stay at the static numbers above, and the
    # opt-in cost is bounded (T5, same static baseline as T4)
    trace_total, trace_per = time_traced_sweep(scen_n)
    rows.append({"replicas": f"{scen_n} (traced)",
                 "total_s": round(trace_total, 4),
                 "per_replica_ms": round(trace_per * 1e3, 3),
                 "replicas_per_s": round(scen_n / trace_total, 1)})

    # workflow (DAG) engine: chain vs independent at the same N, plus
    # the inert-parents run that isolates the has_deps machinery (T7)
    chain_per, inert_per, plain_per = time_workflow_sweep(scen_n)
    for label, per in (("chain DAG", chain_per),
                       ("independent + deps machinery", inert_per),
                       ("independent, mct", plain_per)):
        rows.append({"replicas": f"{scen_n} ({label})",
                     "total_s": round(per * scen_n, 4),
                     "per_replica_ms": round(per * 1e3, 3),
                     "replicas_per_s": round(1 / per, 1)})

    # learned-policy dispatch: MLP with the MCT warm start vs MCT itself
    # (identical decisions; difference = feature build + forward pass)
    mct_per, mlp_per = time_learned_dispatch(scen_n)
    rows.append({"replicas": f"{scen_n} (mct, grouped)",
                 "total_s": round(mct_per * scen_n, 4),
                 "per_replica_ms": round(mct_per * 1e3, 3),
                 "replicas_per_s": round(1 / mct_per, 1)})
    rows.append({"replicas": f"{scen_n} (learned mlp, grouped)",
                 "total_s": round(mlp_per * scen_n, 4),
                 "per_replica_ms": round(mlp_per * 1e3, 3),
                 "replicas_per_s": round(1 / mlp_per, 1)})

    checks = {
        "T1_jit_beats_python_ref": bool(per_replica_1 < ref_per_replica),
        "T2_vmap_amortizes": bool(per_replica_big
                                  < 2 * rows[0]["per_replica_ms"]),
        "T3_grouping_beats_batched_switch": bool(
            grouped_per * 1e3 < per_replica_big),
        "T4_scenario_overhead_bounded": bool(
            scen_per * 1e3 < 4 * static_same_n),
        "T5_trace_overhead_bounded": bool(
            trace_per * 1e3 < 3 * static_same_n),
        "T6_learned_dispatch_overhead_bounded": bool(mlp_per < 3 * mct_per),
        "T7_has_deps_overhead_bounded": bool(inert_per < 2 * plain_per),
    }
    payload = {"rows": rows,
               "ref_per_replica_ms": round(ref_per_replica * 1e3, 2),
               "checks": checks}
    save_result("bench_engine", payload, out_dir)
    print("\n## bench_engine — replica throughput "
          f"(python ref: {ref_per_replica*1e3:.1f} ms/replica)")
    print(md_table(rows))
    print("checks:", checks)
    return payload


if __name__ == "__main__":
    run()
