"""Simulator engine throughput: the "cheap controlled studies" claim.

The paper's motivation is that real-infrastructure studies are cost- and
time-prohibitive.  The quantitative claim of this reproduction is that
the vectorized engine makes *simulated* studies cheap at scale:

  T1. one jit'd replica beats the plain-Python reference engine;
  T2. vmapped replicas amortize: events/sec grows ~linearly with the
      replica count until the host saturates (on TPU this axis is then
      sharded over the pod — launch/experiment.py);
  ...
  T8. the ExperimentSpec executable cache works: building + running a
      SECOND same-shape spec skips retracing entirely and is >= 5x
      faster than the first (docs/experiments.md);
  T9. the streaming window engine's per-task drain cost stays flat
      (< 1.5x drift) when total traffic grows 100x at a fixed window —
      memory and per-event cost are O(W), never O(N)
      (docs/streaming.md);
  T10. the in-jit telemetry instruments (core/metrics.py: latency
      histograms + SLO windows + device-side tail quantiles) cost
      < 2x the idle baseline — cheaper than tracing because only the
      queue-depth sample scatters per event (docs/observability.md);
  T11. the chunked Monte-Carlo driver (launch/chunked.py) scales flat:
      per-replica cost at R=100k stays within 1.3x of R=1k (donated
      buffers + device-side SweepAgg reduction keep host and device
      memory O(chunk)), and the async double-buffer actually overlaps —
      host normalize time hidden behind device execution is > 0
      (docs/scaling.md);
  T12. the overhauled drain hot loop (carried machine-available vector,
      incremental queue counters, zero-trip empty drains) schedules a
      dense N=512 batch instance >= 1.5x faster per replica than the
      PR-9 baseline loop (``SimParams(legacy_drain=True)``), bitwise
      the same schedule (docs/engine_perf.md).

All rows run through the declarative spec pipeline (one cached
executable per SimParams) — the same path users take.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import md_table, save_result
from repro.core import engine as E
from repro.core import ref_engine as RE
from repro.core import schedulers as P
from repro.launch import experiment as XP
from repro.launch.sim import make_replicas, run_grouped_sweep

N_TASKS, N_MACHINES = 128, 16

SCEN_AXIS = XP.ScenarioAxis((0.0, 0.05, 0.2), ("nominal", "powersave"),
                            spot_frac=0.5)


def _time_fn(fn, args, ready=lambda out: out["completed"]):
    out = fn(*args)                            # compile + warm
    jax.block_until_ready(ready(out))
    t0 = time.perf_counter()
    out = fn(*args)
    jax.block_until_ready(ready(out))
    return time.perf_counter() - t0


def time_sweep(n_replicas: int) -> tuple[float, float]:
    inputs = make_replicas(n_replicas, N_TASKS, N_MACHINES, seed=0)
    sweep = XP.compile_sweep()
    dt = _time_fn(sweep, inputs + (None, None, None))
    return dt, dt / n_replicas


def time_scenario_sweep(n_replicas: int) -> tuple[float, float]:
    """Dynamic-scenario replicas (failure traces + DVFS + preemption)."""
    spec = XP.ExperimentSpec(
        n_replicas, XP.FleetAxis(N_MACHINES), XP.WorkloadAxis(N_TASKS),
        scenario=SCEN_AXIS,
        policy=XP.PolicyAxis(("mct", "minmin", "ee_mct")), seed=0)
    reps = XP.normalize(spec)
    sweep = XP.compile_experiment(spec)
    dt = _time_fn(sweep, reps.legacy() + (None, None))
    return dt, dt / n_replicas


def time_traced_sweep(n_replicas: int) -> tuple[float, float]:
    """Replicas with in-jit trace capture on (EXPERIMENTS.md §Perf —
    the measured cost of the masked trace writes + snapshots)."""
    inputs = make_replicas(n_replicas, N_TASKS, N_MACHINES, seed=0)
    sweep = XP.compile_sweep(E.SimParams(trace=True))
    dt = _time_fn(sweep, inputs + (None, None, None),
                  ready=lambda out: out[1].n_rows)
    return dt, dt / n_replicas


def time_metrics_sweep(n_replicas: int) -> tuple[float, float]:
    """Replicas with the in-jit telemetry instruments on (T10 — the
    measured cost of the per-event queue-depth scatter + post-loop fold
    + device-side quantile columns; EXPERIMENTS.md §Perf)."""
    inputs = make_replicas(n_replicas, N_TASKS, N_MACHINES, seed=0)
    sweep = XP.compile_sweep(E.SimParams(metrics=True))
    dt = _time_fn(sweep, inputs + (None, None, None))
    return dt, dt / n_replicas


def time_experiment_cache(n_replicas: int) -> tuple[float, float, dict]:
    """T8: end-to-end (build + normalize + run) of two same-shape specs.

    The first spec pays compilation; the second (new seed, same shapes)
    must hit the executable cache AND jax's trace cache — no retracing.
    A dedicated SimParams (max_events pinned) keeps this row's cache
    entry disjoint from the other rows, so the first run really
    compiles.
    """
    params = E.SimParams(max_events=4 * N_TASKS + 17)

    def build_and_run(seed: int) -> float:
        spec = XP.ExperimentSpec(
            n_replicas, XP.FleetAxis(N_MACHINES),
            XP.WorkloadAxis(N_TASKS), scenario=SCEN_AXIS,
            policy=XP.PolicyAxis(("mct", "minmin", "ee_mct")),
            sim=params, seed=seed)
        t0 = time.perf_counter()
        res = XP.run_experiment(spec)
        jax.block_until_ready(res.metrics["completed"])
        return time.perf_counter() - t0

    stats0 = XP.cache_stats()
    t_first = build_and_run(0)
    t_second = build_and_run(1)
    stats = {k: XP.cache_stats()[k] - stats0[k] for k in ("hits", "misses")}
    return t_first, t_second, stats


def time_learned_dispatch(n_replicas: int) -> tuple[float, float]:
    """Learned-policy dispatch overhead, decision-for-decision.

    The MLP policy is run with the MCT-equivalent warm start
    (``neural.mct_mlp_params``), so both groups take *identical*
    scheduling decisions and event trajectories — the timing difference
    is purely the per-drain-step feature build + forward pass.  Both use
    the policy-grouped path so the heuristic baseline doesn't pay for
    the learned branch (batched lax.switch computes every branch).
    """
    from repro.core import neural as NN
    pp = NN.mct_mlp_params()
    base = make_replicas(n_replicas, N_TASKS, N_MACHINES,
                         policies=["mct"], seed=0)
    learned = base[:3] + (jnp.full_like(base[3], P.POLICY_IDS["mlp"]),)
    times = []
    for inputs, kw in ((base, {}), (learned, {"policy_params": pp})):
        run_grouped_sweep(inputs, **kw)              # compile + warm
        t0 = time.perf_counter()
        run_grouped_sweep(inputs, **kw)
        times.append((time.perf_counter() - t0) / n_replicas)
    return times[0], times[1]                        # (mct, mlp) s/replica


def time_workflow_sweep(n_replicas: int) -> tuple[float, float, float]:
    """DAG-engine rows (docs/workflows.md, EXPERIMENTS.md §Perf).

    Three per-replica timings at the same N, all single-policy (mct) so
    the drain logic is identical:

    * ``chain``   — a fully sequential chain workflow (the dependency-
      release phase is doing maximal work: one release per task);
    * ``inert``   — the *independent* workload run with an all(-1)
      parent table, i.e. the ``has_deps`` machinery compiled in but
      semantically idle — the pure machinery cost T7 bounds;
    * ``plain``   — the same independent workload with ``parents=None``
      (the pre-DAG engine, T7's baseline).
    """
    wf_spec = XP.ExperimentSpec(
        n_replicas, XP.FleetAxis(N_MACHINES),
        XP.WorkloadAxis(N_TASKS, shapes=("chain",)),
        policy=XP.PolicyAxis(("mct",)), seed=0)
    wf = XP.normalize(wf_spec)
    sweep = XP.compile_sweep()
    base = make_replicas(n_replicas, N_TASKS, N_MACHINES,
                         policies=["mct"], seed=0)
    inert_parents = jnp.full((n_replicas, N_TASKS, 1), -1, jnp.int32)
    times = []
    for args in ((wf.tasks, wf.mtype, wf.tables, wf.policy_ids, None,
                  wf.parents, None),
                 base + (None, inert_parents, None),
                 base + (None, None, None)):
        times.append(_time_fn(sweep, args) / n_replicas)
    return times[0], times[1], times[2]        # (chain, inert, plain)


def time_streaming_drain(n_small: int, factor: int = 100,
                         window: int = 64) -> tuple[float, float]:
    """T9: streaming per-task drain cost at fixed W vs total traffic.

    Times ``streaming.simulate_stream`` (warm — compile excluded) on the
    same Poisson family at N and factor*N with the SAME window and
    chunk.  The window engine's state is O(W), so the per-task cost must
    not drift as N grows — the unlocking property for fleet-scale
    traffic (ROADMAP item 1, docs/streaming.md)."""
    from repro.core import streaming as STR
    from repro.core.eet import synth_eet
    from repro.core.workload import poisson_workload
    rng = np.random.default_rng(0)
    eet = synth_eet(4, 4, inconsistency=0.3, seed=0)
    power = np.stack([rng.uniform(20, 60, 4), rng.uniform(80, 300, 4)],
                     axis=1).astype(np.float32)
    mtype = rng.integers(0, 4, 8)
    per = []
    for n in (n_small, n_small * factor):
        wl = poisson_workload(n, rate=8.0, n_task_types=4,
                              mean_eet=eet.eet.mean(1), slack=4.0,
                              seed=1)

        def go():
            res = STR.simulate_stream(wl, eet, power, mtype,
                                      policy="mct", window=window,
                                      chunk=window, lcap=3)
            jax.block_until_ready(res.ws.agg.retired)
            assert int(res.ws.agg.retired) == n
            return res

        go()                                   # compile + warm
        t0 = time.perf_counter()
        go()
        per.append((time.perf_counter() - t0) / n)
    return per[0], per[1]


def time_chunked_sweep(n_small: int, n_big: int, chunk: int = 250):
    """T11: chunked driver per-replica cost at R=n_small vs R=n_big.

    One small experiment cell (16 tasks, 4 machines, single policy) so
    the replica axis is the only thing that grows.  Both runs go through
    ``run_experiment(spec, chunk=...)`` — the donated double-buffered
    driver folding the device-side SweepAgg — after a warm run that pays
    the chunk-shaped compilation.  Returns the two per-replica wall
    times plus the big run's :class:`chunked.ChunkedStats` (whose
    ``overlap_s`` proves host normalize was hidden behind device
    execution).
    """
    spec = XP.ExperimentSpec(
        n_small, XP.FleetAxis(4), XP.WorkloadAxis(16),
        policy=XP.PolicyAxis(("mct",)), seed=0)
    # compile + warm with the same chunk shape (cache key = SimParams +
    # chunk geometry, so both timed runs are pure cache hits)
    XP.run_experiment(spec.with_(n_replicas=2 * chunk), chunk=chunk)
    per, stats = [], None
    for n, seed in ((n_small, 0), (n_big, 1)):
        t0 = time.perf_counter()
        res = XP.run_experiment(spec.with_(n_replicas=n, seed=seed),
                                chunk=chunk)
        per.append((time.perf_counter() - t0) / n)
        stats = res.chunked
    return per[0], per[1], stats


def _dense_batch_inputs(n_replicas: int, n_tasks: int, n_machines: int,
                        policy: str = "mct", seed: int = 0):
    """E2C batch-mode instance: every task arrives at t=0, so the first
    event's drain schedules the whole queue in one deep pass."""
    tt, mt, tb, pid = make_replicas(n_replicas, n_tasks, n_machines,
                                    policies=[policy], seed=seed)
    fields = {f: getattr(tt, f) for f in tt.__dataclass_fields__}
    fields["arrival"] = jnp.zeros_like(tt.arrival)
    return type(tt)(**fields), mt, tb, pid


def time_hot_loop(n_tasks: int, n_machines: int = N_MACHINES,
                  lcap: int | None = None, n_replicas: int = 4,
                  reps: int = 10) -> dict:
    """T12: the overhauled drain hot loop vs the PR-9 baseline.

    Isolates the scheduler drain on a dense batch instance (all N tasks
    in the batch queue at t=0; ``lcap`` sized so one drain schedules
    everything) — per replica the loop runs N dispatch->apply trips,
    the path the hot-loop overhaul rewrote.  Three configs, identical
    decisions (bitwise — tests/test_drain_kway.py):

    * ``legacy_drain=True`` — the PR-9 loop: O(N*M) machine_available
      rebuild inside every dispatch plus the O(N) status-scan bound;
    * ``drain_k=1`` — the default hot path: machine-available carried
      through the loop (one O(M) update per decision), bound from the
      incremental ``n_batch`` counter, empty queues drain in zero trips;
    * ``drain_k=8`` — the K-way speculative width, measured for the
      record: on a CPU host it trades a few large-tensor ops per
      decision for many small ones and loses (docs/engine_perf.md).

    Returns per-replica seconds per config.  Policy id is a
    compile-time constant (grouped-dispatch analog), so the switch
    compiles to the single mct branch.
    """
    from repro.core import state as S
    if lcap is None:
        lcap = max(4, -(-n_tasks // n_machines))
    tt, mt, tb, _ = _dense_batch_inputs(n_replicas, n_tasks, n_machines)
    pid_const = jnp.int32(P.POLICY_IDS["mct"])

    def harness(params):
        def one(tasks, mtype, table):
            st = S.init_state(tasks, mtype, None, None)
            st = E._arrivals(st, params.qcap)
            st = E._drain(st, table, pid_const, params)
            return st.tasks.status, st.machines.busy_until
        return jax.jit(jax.vmap(one))

    out = {}
    for label, params in (
            ("legacy", E.SimParams(lcap=lcap, legacy_drain=True)),
            ("hot", E.SimParams(lcap=lcap, drain_k=1)),
            ("spec_k8", E.SimParams(lcap=lcap, drain_k=8))):
        fn = harness(params)
        res = fn(tt, mt, tb)
        jax.block_until_ready(res)
        t0 = time.perf_counter()
        for _ in range(reps):
            res = fn(tt, mt, tb)
        jax.block_until_ready(res)
        out[label] = (time.perf_counter() - t0) / reps / n_replicas
    return out


def phase_breakdown(n_tasks: int = 512, n_machines: int = N_MACHINES,
                    n_replicas: int = 4, reps: int = 300) -> dict:
    """Measured per-event phase costs (docs/engine_perf.md §breakdown).

    Times each event phase standalone (jit + vmap over the replica
    axis) on the post-drain dense state — every task queued or running,
    the steady state of the batch regime.  Values are microseconds per
    call for the whole replica batch; each includes the per-call jit
    dispatch overhead (~tens of us on CPU), so compare differences, not
    absolutes — inside ``run_sim``'s while loop the phases fuse into
    one XLA computation.
    """
    from repro.core import state as S
    lcap = max(4, -(-n_tasks // n_machines))
    tt, mt, tb, _ = _dense_batch_inputs(n_replicas, n_tasks, n_machines)
    pid_const = jnp.int32(P.POLICY_IDS["mct"])
    params = E.SimParams(lcap=lcap, drain_k=1)

    @jax.jit
    @jax.vmap
    def mk(tasks, mtype, table):
        st = S.init_state(tasks, mtype, None, None)
        st = E._arrivals(st, params.qcap)
        st = E._drain(st, table, pid_const, params)
        st = E._start_tasks(st, table)
        return st
    st0 = mk(tt, mt, tb)
    jax.block_until_ready(st0)

    phases = {
        "next_event_time": lambda st, table: E._next_event_time(st),
        "completions": lambda st, table: E._completions(st, table),
        "arrivals": lambda st, table: E._arrivals(st, params.qcap),
        "deadline_drops": lambda st, table: E._deadline_drops(st, table),
        "drain_no_work": lambda st, table: E._drain(st, table, pid_const,
                                                    params),
        "start_tasks": lambda st, table: E._start_tasks(st, table),
    }
    out = {"n_tasks": n_tasks, "n_machines": n_machines,
           "n_replicas": n_replicas, "unit": "us_per_call",
           "phases_us": {}}
    for name, f in phases.items():
        g = jax.jit(jax.vmap(f))
        res = g(st0, tb)
        jax.block_until_ready(res)
        t0 = time.perf_counter()
        for _ in range(reps):
            res = g(st0, tb)
        jax.block_until_ready(res)
        out["phases_us"][name] = round(
            (time.perf_counter() - t0) / reps * 1e6, 1)
    return out


def run(out_dir=None, smoke: bool = False) -> dict:
    # ref engine indexes tuple fields positionally; rebuild host-side
    inputs = make_replicas(2, N_TASKS, N_MACHINES, seed=0)
    t0 = time.perf_counter()
    for i in range(2):
        arr = jax.tree.map(lambda x: np.asarray(x[i]), inputs)
        tt, mt, tb, pid = arr
        RE.simulate_ref(tt.arrival, tt.type_id, tt.deadline, tb.eet,
                        tb.power, mt, policy=P.POLICY_NAMES[int(pid)],
                        noise=tb.noise)
    ref_per_replica = (time.perf_counter() - t0) / 2

    sizes = (1, 8, 32) if smoke else (1, 8, 64, 256)
    big = sizes[-1]
    rows = []
    per_replica_1 = None
    for n in sizes:
        total, per = time_sweep(n)
        if n == 1:
            per_replica_1 = per
        rows.append({"replicas": n, "total_s": round(total, 4),
                     "per_replica_ms": round(per * 1e3, 3),
                     "replicas_per_s": round(n / total, 1)})
    per_replica_big = rows[-1]["per_replica_ms"]

    # policy-grouped variant: batched lax.switch computes every policy
    # branch per replica; grouping makes the policy a compile-time
    # constant (see launch/sim.run_grouped_sweep)
    inputs = make_replicas(big, N_TASKS, N_MACHINES, seed=0)
    run_grouped_sweep(inputs)                   # compile + warm
    t0 = time.perf_counter()
    run_grouped_sweep(inputs)
    grouped_per = (time.perf_counter() - t0) / big
    rows.append({"replicas": f"{big} (policy-grouped)",
                 "total_s": round(grouped_per * big, 4),
                 "per_replica_ms": round(grouped_per * 1e3, 3),
                 "replicas_per_s": round(1 / grouped_per, 1)})

    # dynamic-scenario variant: availability traces + DVFS + preemption
    # add an event phase and masks; T4 bounds their overhead
    scen_n = 8 if smoke else 64
    scen_total, scen_per = time_scenario_sweep(scen_n)
    rows.append({"replicas": f"{scen_n} (scenario)",
                 "total_s": round(scen_total, 4),
                 "per_replica_ms": round(scen_per * 1e3, 3),
                 "replicas_per_s": round(scen_n / scen_total, 1)})
    static_same_n = next(r for r in rows
                         if r["replicas"] == scen_n)["per_replica_ms"]

    # traced variant: TraceBuffer recording inside the jitted loop; the
    # default-off path must stay at the static numbers above, and the
    # opt-in cost is bounded (T5, same static baseline as T4)
    trace_total, trace_per = time_traced_sweep(scen_n)
    rows.append({"replicas": f"{scen_n} (traced)",
                 "total_s": round(trace_total, 4),
                 "per_replica_ms": round(trace_per * 1e3, 3),
                 "replicas_per_s": round(scen_n / trace_total, 1)})

    # telemetry variant: latency histograms + SLO windows + device-side
    # quantiles inside the jitted loop; default-off compiles identical
    # HLO (tests/test_metrics.py), opt-in cost is bounded (T10)
    metrics_total, metrics_per = time_metrics_sweep(scen_n)
    rows.append({"replicas": f"{scen_n} (metrics)",
                 "total_s": round(metrics_total, 4),
                 "per_replica_ms": round(metrics_per * 1e3, 3),
                 "replicas_per_s": round(scen_n / metrics_total, 1)})

    # workflow (DAG) engine: chain vs independent at the same N, plus
    # the inert-parents run that isolates the has_deps machinery (T7)
    chain_per, inert_per, plain_per = time_workflow_sweep(scen_n)
    for label, per in (("chain DAG", chain_per),
                       ("independent + deps machinery", inert_per),
                       ("independent, mct", plain_per)):
        rows.append({"replicas": f"{scen_n} ({label})",
                     "total_s": round(per * scen_n, 4),
                     "per_replica_ms": round(per * 1e3, 3),
                     "replicas_per_s": round(1 / per, 1)})

    # learned-policy dispatch: MLP with the MCT warm start vs MCT itself
    # (identical decisions; difference = feature build + forward pass)
    mct_per, mlp_per = time_learned_dispatch(scen_n)
    rows.append({"replicas": f"{scen_n} (mct, grouped)",
                 "total_s": round(mct_per * scen_n, 4),
                 "per_replica_ms": round(mct_per * 1e3, 3),
                 "replicas_per_s": round(1 / mct_per, 1)})
    rows.append({"replicas": f"{scen_n} (learned mlp, grouped)",
                 "total_s": round(mlp_per * scen_n, 4),
                 "per_replica_ms": round(mlp_per * 1e3, 3),
                 "replicas_per_s": round(1 / mlp_per, 1)})

    # ExperimentSpec executable cache: build+run a spec twice (new seed,
    # same shapes) — the second must skip retracing entirely (T8).
    # Fixed small replica count: the check isolates compile-vs-cached
    # dispatch, so execution time must not drown the compile term.
    cache_n = 8
    cache_first, cache_second, cache_stats = time_experiment_cache(cache_n)
    for label, total in (("spec, first build+run", cache_first),
                         ("spec, same-shape re-run", cache_second)):
        rows.append({"replicas": f"{cache_n} ({label})",
                     "total_s": round(total, 4),
                     "per_replica_ms": round(total / cache_n * 1e3, 3),
                     "replicas_per_s": round(cache_n / total, 1)})

    # streaming window engine: same window, traffic x100 — the per-task
    # drain cost must stay flat because live state is O(W), not O(N) (T9)
    stream_n = 32 if smoke else 64
    stream_factor = 100
    stream_small, stream_big = time_streaming_drain(stream_n,
                                                    stream_factor)
    for label, n, per in (
            ("streaming W=64", stream_n, stream_small),
            ("streaming W=64", stream_n * stream_factor, stream_big)):
        rows.append({"replicas": f"{n} tasks ({label})",
                     "total_s": round(per * n, 4),
                     "per_replica_ms": round(per * 1e3, 3),
                     "replicas_per_s": round(1 / per, 1)})

    # chunked Monte-Carlo driver: the replica axis grows 10-100x at a
    # fixed chunk; per-replica cost must stay flat and the async driver
    # must actually overlap normalize with device execution (T11)
    chunk_small, chunk_big = 1000, (10_000 if smoke else 100_000)
    chunked_small, chunked_big, chunked_stats = time_chunked_sweep(
        chunk_small, chunk_big)
    for n, per in ((chunk_small, chunked_small),
                   (chunk_big, chunked_big)):
        rows.append({"replicas": f"{n} (chunked, chunk=250)",
                     "total_s": round(per * n, 4),
                     "per_replica_ms": round(per * 1e3, 3),
                     "replicas_per_s": round(1 / per, 1)})

    # drain hot loop vs the PR-9 baseline on a dense batch instance (T12)
    hot_n = 256 if smoke else 512
    hot = time_hot_loop(hot_n)
    for label in ("legacy", "hot", "spec_k8"):
        per = hot[label]
        rows.append({"replicas": f"{hot_n} tasks (drain {label}, dense)",
                     "total_s": round(per * 4, 4),
                     "per_replica_ms": round(per * 1e3, 3),
                     "replicas_per_s": round(1 / per, 1)})

    # per-event phase cost breakdown — uploaded next to the run ledger
    # (docs/engine_perf.md; CI artifact)
    breakdown = phase_breakdown(hot_n, reps=100 if smoke else 300)
    breakdown["hot_loop"] = {
        k: round(v * 1e3, 3) for k, v in hot.items()}
    breakdown["hot_loop"]["speedup_vs_legacy"] = round(
        hot["legacy"] / hot["hot"], 2)
    save_result("phase_breakdown", breakdown, out_dir)

    checks = {
        "T1_jit_beats_python_ref": bool(per_replica_1 < ref_per_replica),
        "T2_vmap_amortizes": bool(per_replica_big
                                  < 2 * rows[0]["per_replica_ms"]),
        "T3_grouping_beats_batched_switch": bool(
            grouped_per * 1e3 < per_replica_big),
        "T4_scenario_overhead_bounded": bool(
            scen_per * 1e3 < 4 * static_same_n),
        "T5_trace_overhead_bounded": bool(
            trace_per * 1e3 < 3 * static_same_n),
        "T6_learned_dispatch_overhead_bounded": bool(mlp_per < 3 * mct_per),
        "T7_has_deps_overhead_bounded": bool(inert_per < 2 * plain_per),
        "T8_experiment_cache_hits": bool(
            cache_second * 5 <= cache_first
            and cache_stats == {"hits": 1, "misses": 1}),
        "T9_streaming_per_task_flat": bool(
            stream_big < 1.5 * stream_small),
        "T10_metrics_overhead_bounded": bool(
            metrics_per * 1e3 < 2 * static_same_n),
        "T11_chunked_per_replica_flat": bool(
            chunked_big < 1.3 * chunked_small
            and chunked_stats.overlap_s > 0),
        "T12_hot_loop_speedup": bool(hot["legacy"] >= 1.5 * hot["hot"]),
    }
    payload = {"rows": rows,
               "hot_loop": breakdown["hot_loop"],
               "phase_breakdown_us": breakdown["phases_us"],
               "chunked": {
                   "chunk": 250,
                   "n_small": chunk_small,
                   "n_big": chunk_big,
                   "per_replica_small_ms": round(chunked_small * 1e3, 3),
                   "per_replica_big_ms": round(chunked_big * 1e3, 3),
                   "drift": round(chunked_big / chunked_small, 3),
                   "overlap_s": round(chunked_stats.overlap_s, 3),
                   "overlap_frac": round(chunked_stats.overlap_frac, 3)},
               "ref_per_replica_ms": round(ref_per_replica * 1e3, 2),
               "experiment_cache": {
                   "first_s": round(cache_first, 4),
                   "second_s": round(cache_second, 4),
                   "speedup": round(cache_first / cache_second, 1),
                   **cache_stats},
               "streaming": {
                   "window": 64,
                   "n_small": stream_n,
                   "n_big": stream_n * stream_factor,
                   "per_task_small_ms": round(stream_small * 1e3, 4),
                   "per_task_big_ms": round(stream_big * 1e3, 4),
                   "drift": round(stream_big / stream_small, 3)},
               "checks": checks}
    save_result("bench_engine", payload, out_dir)
    print("\n## bench_engine — replica throughput "
          f"(python ref: {ref_per_replica*1e3:.1f} ms/replica)")
    print(md_table(rows))
    print("experiment cache:", payload["experiment_cache"])
    print("chunked:", payload["chunked"])
    print("checks:", checks)
    return payload


if __name__ == "__main__":
    run()
