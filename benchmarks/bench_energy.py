"""Energy-aware scheduling (the FELARE [12] use-case, paper §2).

Compares EE-MET / EE-MCT against their energy-blind counterparts on a
heterogeneous edge where the fast machines burn disproportionately more
power — the regime where the energy/SLO trade-off is real.  Claims:

  E1. EE-MCT uses less active energy than MCT at equal-ish completion;
  E2. idle energy is accounted (total > active);
  E3. the energy ordering is stable across seeds.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import md_table, save_result
from repro.core import engine as E
from repro.core import report as R
from repro.core.eet import EETTable
from repro.core.workload import poisson_workload

# 3 machine types: slow/efficient, medium, fast/hungry (like CPU/GPU/TPU
# edge boxes); EET consistent so "fast" means fast for everything.
EET = EETTable(np.array([
    [4.0, 2.0, 0.8],
    [8.0, 3.5, 1.5],
    [2.0, 1.2, 0.5],
], np.float32))
POWER = np.array([[5., 30.], [10., 90.], [15., 250.]], np.float32)
POLICIES = ["met", "mct", "ee_met", "ee_mct"]


def run(out_dir=None) -> dict:
    rows = []
    per_seed = {p: [] for p in POLICIES}
    for seed in range(5):
        wl = poisson_workload(150, rate=1.2, n_task_types=3,
                              mean_eet=EET.eet.mean(1), slack=5.0,
                              seed=seed)
        mtype = [0, 0, 1, 1, 2, 2]
        for pol in POLICIES:
            st = E.simulate(wl, EET, POWER, mtype, policy=pol)
            rep = R.metrics(st, E.make_tables(EET, POWER, wl.n_tasks))
            per_seed[pol].append(rep)
    for pol in POLICIES:
        reps = per_seed[pol]
        rows.append({
            "policy": pol,
            "completion_rate": round(float(np.mean(
                [r.completion_rate for r in reps])), 4),
            "active_energy_J": round(float(np.mean(
                [r.active_energy for r in reps])), 1),
            "idle_energy_J": round(float(np.mean(
                [r.idle_energy for r in reps])), 1),
            "total_energy_J": round(float(np.mean(
                [r.total_energy for r in reps])), 1),
            "mean_response_s": round(float(np.mean(
                [r.mean_response for r in reps])), 3),
        })
    byp = {r["policy"]: r for r in rows}
    checks = {
        "E1_ee_mct_saves_energy": bool(
            byp["ee_mct"]["active_energy_J"]
            < byp["mct"]["active_energy_J"]),
        "E1b_ee_met_saves_energy": bool(
            byp["ee_met"]["active_energy_J"]
            <= byp["met"]["active_energy_J"]),
        "E2_idle_accounted": bool(
            all(r["total_energy_J"] > r["active_energy_J"]
                for r in rows)),
        "E3_completion_not_collapsed": bool(
            byp["ee_mct"]["completion_rate"]
            >= byp["mct"]["completion_rate"] - 0.1),
    }
    payload = {"rows": rows, "checks": checks}
    save_result("bench_energy", payload, out_dir)
    print("\n## bench_energy — energy-aware vs energy-blind policies")
    print(md_table(rows))
    print("checks:", checks)
    return payload


if __name__ == "__main__":
    run()
