"""Render the §Roofline table from the dry-run records.

Reads results/dryrun/*.json (written by launch/dryrun.py) and emits the
per-(arch x shape x mesh) roofline terms, dominant bottleneck, and
MODEL_FLOPS / HLO_FLOPs utilization ratio — the §Roofline deliverable.
"""
from __future__ import annotations

import glob
import json
import os

from benchmarks.common import md_table, save_result

DRYRUN_DIR = os.environ.get("REPRO_DRYRUN", "results/dryrun")


def load_records(dryrun_dir: str | None = None) -> list[dict]:
    recs = []
    for path in sorted(glob.glob(os.path.join(dryrun_dir or DRYRUN_DIR,
                                              "*.json"))):
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def rows_from(recs: list[dict], mesh: str = "16x16",
              variant: str = "base") -> list[dict]:
    rows = []
    for r in recs:
        if r.get("mesh") != mesh or r.get("variant", "base") != variant:
            continue
        if r["status"] == "skipped":
            rows.append({"arch": r["arch"], "shape": r["shape"],
                         "status": "skipped (" + r["why"][:40] + "...)"})
            continue
        if r["status"] != "ok":
            rows.append({"arch": r["arch"], "shape": r["shape"],
                         "status": "ERROR"})
            continue
        if "roofline" not in r:        # e2c-sim sweep cells: cost only
            rows.append({"arch": r["arch"], "shape": r["shape"],
                         "status": "ok (sim cell — see §Dry-run)"})
            continue
        rl = r["roofline"]
        terms = {"compute": rl["t_compute_s"], "memory": rl["t_memory_s"],
                 "collective": rl["t_collective_s"]}
        dom = max(terms, key=terms.get)
        bound = max(terms.values())
        frac = terms["compute"] / bound if bound > 0 else 0.0
        rows.append({
            "arch": r["arch"], "shape": r["shape"], "status": "ok",
            "t_compute_s": f"{terms['compute']:.3f}",
            "t_memory_s": f"{terms['memory']:.3f}",
            "t_collective_s": f"{terms['collective']:.3f}",
            "bottleneck": dom,
            "roofline_frac": f"{frac:.3f}",
            "useful_flops": r.get("useful_flops_ratio"),
            "mem_gb": r.get("memory", {}).get("total_gb"),
        })
    return rows


def run(out_dir=None, dryrun_dir=None) -> dict:
    recs = load_records(dryrun_dir)
    out = {}
    for mesh in ("16x16", "2x16x16"):
        rows = rows_from(recs, mesh)
        out[mesh] = rows
        if rows:
            print(f"\n## roofline — mesh {mesh} ({len(rows)} cells)")
            print(md_table(rows))
    ok = sum(1 for m in out.values() for r in m if r.get("status") == "ok")
    skipped = sum(1 for m in out.values() for r in m
                  if "skipped" in str(r.get("status")))
    err = sum(1 for m in out.values() for r in m
              if r.get("status") == "ERROR")
    payload = {"tables": out,
               "summary": {"ok": ok, "skipped": skipped, "errors": err}}
    save_result("roofline", payload, out_dir)
    print(f"\nroofline summary: {payload['summary']}")
    return payload


if __name__ == "__main__":
    run()
